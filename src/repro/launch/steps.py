"""Jitted, sharded step builders: train / prefill / decode(serve).

Each builder returns (jit_fn, arg_shapes, in_shardings, out_shardings) so
the dry-run can ``.lower(...).compile()`` against ShapeDtypeStructs and the
real launchers can call the same object with live arrays.

Serving steps run the SAIL path by default: weights SAIL-quantized
(QTensor leaves, ql bits) and the KV cache int8 — the configuration the
paper evaluates; ``quantize=False`` gives the unquantized baseline used
for the §Perf before/after comparison.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist import sharding as sh
from repro.models import encdec, lm
from repro.models.common import ModelConfig
from repro.models.sail_linear import QuantPolicy, quantize_params
from repro.optim.adamw import AdamW, cosine_schedule
from repro.launch import specs as sp


@dataclasses.dataclass
class BuiltStep:
    fn: Any                    # jitted function
    args: tuple                # ShapeDtypeStruct pytrees (lower(*args))
    in_shardings: tuple
    out_shardings: Any
    meta: Dict[str, Any]


def _cast_bf16(params):
    return jax.tree_util.tree_map(
        lambda p: p.astype(jnp.bfloat16)
        if (hasattr(p, "dtype") and p.dtype == jnp.float32 and p.ndim >= 2)
        else p, params)


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------

def auto_microbatches(cfg: ModelConfig, mesh: Mesh, shape: str,
                      budget_bytes: float = 3e9) -> int:
    """Grad-accumulation factor sized so the per-layer residual-stream
    carries saved by the layer scan (n_layers x [B_local, T, D] bf16) fit
    the activation budget — the dominant train-memory term after remat +
    chunked CE (measured via dry-run memory analysis)."""
    s = sp.SHAPES[shape]
    dp = 1
    for a in ("pod", "data"):
        dp *= dict(zip(mesh.axis_names, mesh.devices.shape)).get(a, 1)
    b_local = max(1, s["batch"] // dp)
    n_layers = cfg.n_layers + cfg.n_enc_layers
    carries = n_layers * b_local * s["seq"] * cfg.d_model * 2
    m = 1
    while carries / m > budget_bytes and m < b_local:
        m *= 2
    return m


def build_train_step(cfg: ModelConfig, mesh: Mesh,
                     shape: str = "train_4k",
                     fsdp: Optional[bool] = None,
                     microbatches: Optional[int] = None,
                     bf16_compute: bool = True,
                     peak_lr: float = 3e-4,
                     remat_policy: str = "full") -> BuiltStep:
    plan = sh.make_plan(mesh, cfg, fsdp)
    if microbatches is None:
        microbatches = auto_microbatches(cfg, mesh, shape)
    opt = AdamW(learning_rate=cosine_schedule(peak_lr, 100, 10000))

    if cfg.family == "encdec":
        base_loss = lambda p, b: encdec.loss_fn(p, b, cfg)
        init = encdec.init_params
    else:
        base_loss = lambda p, b: lm.loss_fn(p, b, cfg)
        init = lm.init_params

    def loss_fn(params, batch):
        # params arrive pre-cast (see train_step): the bf16 cast must sit
        # OUTSIDE the microbatch scan or GSPMD all-gathers f32 master
        # weights per micro-step (§Perf B2: 2x the FSDP gather bytes)
        if bf16_compute and "prefix_embeds" in batch:
            batch = dict(batch,
                         prefix_embeds=batch["prefix_embeds"].astype(
                             jnp.bfloat16))
        return base_loss(params, batch)

    def train_step(params, opt_state, batch):
        # bf16 cast hoisted out of the microbatch scan (§Perf B2): FSDP
        # all-gathers then move bf16 shards; d(cast)/dp = 1, so grads wrt
        # the cast params are the grads wrt the masters.
        fp = _cast_bf16(params) if bf16_compute else params
        if microbatches > 1:
            def micro(carry, mb):
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    fp, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, gg: a + gg.astype(jnp.float32), carry, g)
                return acc, (l, m["nll"])
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape((microbatches,
                                     x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)
            grads, (ls, nlls) = jax.lax.scan(micro, zero, mbs)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            loss, nll = ls.mean(), nlls.mean()
        else:
            (loss, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                fp, batch)
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grads)
            nll = m["nll"]
        updates, opt_state, gnorm = opt.update(grads, opt_state, params)
        params = opt.apply(params, updates)
        metrics = {"loss": loss.astype(jnp.float32),
                   "nll": nll.astype(jnp.float32),
                   "grad_norm": gnorm.astype(jnp.float32),
                   "step": opt_state.step}
        return params, opt_state, metrics

    key = jax.random.PRNGKey(0)
    p_shapes = jax.eval_shape(lambda: init(key, cfg))
    o_shapes = jax.eval_shape(lambda: opt.init(p_shapes))
    b_shapes = sp.input_specs(cfg, shape)

    p_sh = sh.param_shardings(mesh, p_shapes, cfg, plan)
    o_sh = type(o_shapes)(
        step=NamedSharding(mesh, P()),
        mu=sh.param_shardings(mesh, o_shapes.mu, cfg, plan),
        nu=sh.param_shardings(mesh, o_shapes.nu, cfg, plan))
    b_sh = sh.data_shardings(mesh, b_shapes, plan)
    m_sh = jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()),
                                  {"loss": 0., "nll": 0., "grad_norm": 0.,
                                   "step": 0})

    fn = jax.jit(train_step,
                 in_shardings=(p_sh, o_sh, b_sh),
                 out_shardings=(p_sh, o_sh, m_sh),
                 donate_argnums=(0, 1))
    return BuiltStep(fn=fn, args=(p_shapes, o_shapes, b_shapes),
                     in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, m_sh),
                     meta={"plan": plan, "optimizer": opt, "init": init,
                           "kind": "train"})


# ---------------------------------------------------------------------------
# serving steps (SAIL path)
# ---------------------------------------------------------------------------

def _serve_params_shapes(cfg: ModelConfig, quantize: bool, ql: int):
    key = jax.random.PRNGKey(0)
    init = encdec.init_params if cfg.family == "encdec" else lm.init_params
    p_shapes = jax.eval_shape(lambda: init(key, cfg))
    if quantize:
        policy = QuantPolicy(bits=ql)
        p_shapes = jax.eval_shape(
            lambda t: quantize_params(t, policy)[0], p_shapes)
    return p_shapes


def build_prefill_step(cfg: ModelConfig, mesh: Mesh,
                       shape: str = "prefill_32k", quantize: bool = True,
                       ql: int = 4, quant_kv: bool = True) -> BuiltStep:
    plan = sh.make_plan(mesh, cfg, fsdp=False)
    seq = sp.SHAPES[shape]["seq"]
    clen = max(sp.decode_cache_len(cfg, shape), 1)

    if cfg.family == "encdec":
        def prefill_step(params, batch):
            return encdec.serve_prefill(params, batch["frames"], cfg,
                                        cache_len=clen, quant_kv=quant_kv)
    else:
        def prefill_step(params, batch):
            logits, cache = lm.prefill(
                params, batch["tokens"], cfg, cache_len=clen,
                quant_kv=quant_kv,
                prefix_embeds=batch.get("prefix_embeds"),
                lengths=batch.get("lengths"),
                moe_mode="dispatch" if cfg.family == "moe" else "dense")
            return logits, cache

    p_shapes = _serve_params_shapes(cfg, quantize, ql)
    b_shapes = sp.input_specs(cfg, shape)
    p_sh = sh.param_shardings(mesh, p_shapes, cfg, plan)
    b_sh = sh.data_shardings(mesh, b_shapes, plan)
    out_shapes = jax.eval_shape(prefill_step, p_shapes, b_shapes)
    if cfg.family == "encdec":
        out_sh = sh.cache_shardings(mesh, out_shapes, plan)
    else:
        out_sh = (sh.data_shardings(mesh, out_shapes[0], plan),
                  sh.cache_shardings(mesh, out_shapes[1], plan))

    fn = jax.jit(prefill_step, in_shardings=(p_sh, b_sh),
                 out_shardings=out_sh)
    return BuiltStep(fn=fn, args=(p_shapes, b_shapes),
                     in_shardings=(p_sh, b_sh), out_shardings=out_sh,
                     meta={"plan": plan, "kind": "prefill",
                           "cache_len": clen})


def build_serve_step(cfg: ModelConfig, mesh: Mesh, shape: str = "decode_32k",
                     quantize: bool = True, ql: int = 4,
                     quant_kv: bool = True) -> BuiltStep:
    """One-token decode against a seq_len KV cache (the SAIL hot loop)."""
    plan = sh.make_plan(mesh, cfg, fsdp=False)

    if cfg.family == "encdec":
        def serve_step(params, tokens, cache):
            return encdec.serve_decode_step(params, tokens, cache, cfg,
                                            quant_kv=quant_kv)
    else:
        def serve_step(params, tokens, cache):
            return lm.decode_step(params, tokens, cache, cfg,
                                  quant_kv=quant_kv, moe_mode="dense")

    p_shapes = _serve_params_shapes(cfg, quantize, ql)
    t_shapes = sp.input_specs(cfg, shape)["tokens"]
    c_shapes = sp.cache_specs(cfg, shape, quant_kv)
    p_sh = sh.param_shardings(mesh, p_shapes, cfg, plan)
    t_sh = NamedSharding(mesh, sh._trim_spec(P(plan.dp, None),
                                             t_shapes.shape, mesh))
    c_sh = sh.cache_shardings(mesh, c_shapes, plan)
    logits_shape = jax.ShapeDtypeStruct(
        (t_shapes.shape[0], cfg.vocab), jnp.float32)
    l_sh = NamedSharding(mesh, sh._trim_spec(P(plan.dp, plan.tp_axis),
                                             logits_shape.shape, mesh))
    fn = jax.jit(serve_step, in_shardings=(p_sh, t_sh, c_sh),
                 out_shardings=(l_sh, c_sh), donate_argnums=(2,))
    return BuiltStep(fn=fn, args=(p_shapes, t_shapes, c_shapes),
                     in_shardings=(p_sh, t_sh, c_sh),
                     out_shardings=(l_sh, c_sh),
                     meta={"plan": plan, "kind": "decode"})


def build_step(cfg: ModelConfig, mesh: Mesh, shape: str,
               **kw) -> BuiltStep:
    kind = sp.SHAPES[shape]["kind"]
    if kind == "train":
        allowed = {k: v for k, v in kw.items()
                   if k in ("fsdp", "microbatches", "bf16_compute",
                            "remat_policy")}
        return build_train_step(cfg, mesh, shape, **allowed)
    allowed = {k: v for k, v in kw.items()
               if k in ("quantize", "ql", "quant_kv")}
    if kind == "prefill":
        return build_prefill_step(cfg, mesh, shape, **allowed)
    return build_serve_step(cfg, mesh, shape, **allowed)
