"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3_2_1b \
        --steps 1000 --ckpt /path/ckpt [--smoke] [--mesh dxm]

On a real multi-host slice this binary runs per host (jax.distributed
initializes from the cluster env); on this box it drives the same code on
however many devices exist.  Fault tolerance: checkpoints + SIGTERM
handling via repro.training.loop; elastic restart re-shards onto the
current mesh.
"""
from __future__ import annotations

import argparse

import jax


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--mesh", default=None,
                    help="DxM device mesh, e.g. 4x2 (default: all x 1)")
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--distributed", action="store_true",
                    help="jax.distributed.initialize() from cluster env")
    args = ap.parse_args()

    if args.distributed:
        jax.distributed.initialize()

    import repro.configs as C
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.launch.mesh import make_mesh, describe
    from repro.launch.steps import build_train_step
    from repro.training.loop import TrainLoop, TrainLoopConfig

    cfg = C.get_smoke(args.arch) if args.smoke else C.get_config(args.arch)
    n_dev = len(jax.devices())
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
    else:
        d, m = n_dev, 1
    mesh = make_mesh((d, m), ("data", "model"))
    print(describe(mesh))

    built = build_train_step(cfg, mesh, microbatches=args.microbatches or 1,
                             bf16_compute=False)
    init = built.meta["init"]
    opt = built.meta["optimizer"]
    params = init(jax.random.PRNGKey(0), cfg)
    opt_state = opt.init(params)
    print(f"{cfg.name}: "
          f"{sum(x.size for x in jax.tree_util.tree_leaves(params))/1e6:.1f}M"
          f" params, {args.steps} steps")

    data = SyntheticLM(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.global_batch,
        n_hosts=jax.process_count(), host_id=jax.process_index(),
        frontend=cfg.frontend,
        frontend_tokens=cfg.vision_tokens if cfg.frontend == "vision" else 0,
        d_model=cfg.d_model))

    loop = TrainLoop(built.fn, params, opt_state, data,
                     TrainLoopConfig(total_steps=args.steps,
                                     checkpoint_dir=args.ckpt),
                     shardings=(built.in_shardings[0],
                                built.in_shardings[1]))
    loop.install_signal_handlers()
    if loop.maybe_restore():
        print(f"resumed from step {loop.step}")
    with mesh:
        result = loop.run()
    print(f"finished at step {result['final_step']} "
          f"(preempted={result['preempted']})")


if __name__ == "__main__":
    main()
