import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import (jax locks the
# device count at first initialization).

"""Multi-pod dry-run: prove the distribution config is coherent.
(No ``from __future__`` import here: the XLA_FLAGS lines must stay first.)

For every (architecture x input-shape) cell, lower + compile the step on
the single-pod 16x16 mesh and the 2x16x16 multi-pod mesh, record
``memory_analysis()`` (fits?), ``cost_analysis()`` (FLOPs / bytes for the
roofline), and the collective-bytes breakdown parsed from the compiled HLO.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --out runs/dryrun
Options: --no-quant (baseline serving path), --ql N, --fsdp {auto,on,off}.
"""
import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax  # noqa: F401  (must initialize after the XLA_FLAGS above)
import numpy as np


COLLECTIVE_RE = re.compile(
    r"(\w[\w.-]*)\s*=\s*(?:\([^)]*\)|\S+)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.I)

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(pred|[sufbc]\d+|bf16)\[([\d,]*)\]")


def _op_output_bytes(line: str) -> int:
    """Sum operand/result tensor bytes named on an HLO text line."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(line.split("=", 1)[0] or line):
        n = int(np.prod([int(d) for d in dims.split(",") if d])) if dims \
            else 1
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


_COLL_LINE = re.compile(
    r"=\s*(?P<rtype>.*?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start|-done)?\(")


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Bytes moved by each collective kind, parsed from the compiled
    (SPMD-partitioned, per-device) HLO: result-shape accounting — for
    all-gather that is bytes received per device, for all-reduce /
    reduce-scatter / all-to-all / permute the per-device payload."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_LINE.search(line)
        if not m or m.group("suffix") == "-done":
            continue
        b = _op_output_bytes(m.group("rtype"))
        base = m.group("op")
        out[base] = out.get(base, 0) + b
    return out


def run_cell(arch: str, shape: str, mesh_kind: str,
             quantize: bool = True, ql: int = 4,
             fsdp: Optional[bool] = None, save_hlo: Optional[str] = None,
             step_options: Optional[Dict[str, Any]] = None
             ) -> Dict[str, Any]:
    import repro.configs as C
    from repro.launch import specs as sp
    from repro.launch.mesh import make_production_mesh, describe
    from repro.launch.steps import build_step

    cfg = C.get_config(arch)
    rec: Dict[str, Any] = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "quantize": quantize, "ql": ql}
    if not sp.cell_is_runnable(cfg, shape):
        rec["status"] = "skipped"
        rec["reason"] = ("full-attention arch: 500k decode requires "
                         "sub-quadratic attention (DESIGN.md)")
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    try:
        built = build_step(cfg, mesh, shape, quantize=quantize, ql=ql,
                           fsdp=fsdp, **(step_options or {}))
        with mesh:
            lowered = built.fn.lower(*built.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)

        # trip-count-aware reanalysis (XLA cost_analysis counts while-loop
        # bodies once — see benchmarks/hlo_cost.py)
        try:
            from benchmarks.hlo_cost import analyze as hlo_analyze
            parsed = hlo_analyze(hlo)
        except Exception as e:  # keep the raw numbers if parsing breaks
            parsed = {"flops": -1.0, "bytes": -1.0, "coll_bytes": -1.0,
                      "error": str(e)}

        rec.update(
            status="ok",
            mesh_desc=describe(mesh),
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            flops_per_device=float(cost.get("flops", -1)) if cost else -1,
            bytes_per_device=float(cost.get("bytes accessed", -1))
            if cost else -1,
            collective_bytes=coll,
            collective_total=int(sum(coll.values())),
            flops_parsed=parsed.get("flops", -1.0),
            bytes_parsed=parsed.get("bytes", -1.0),
            coll_parsed=parsed.get("coll_bytes", -1.0),
            coll_by_kind={k.replace("coll_", ""): v
                          for k, v in parsed.items()
                          if k.startswith("coll_") and k != "coll_bytes"},
        )
        if mem is not None:
            for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                      "output_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes",
                      "peak_memory_in_bytes"):
                v = getattr(mem, k, None)
                if v is not None:
                    rec[k] = int(v)
        if save_hlo:
            with open(save_hlo, "w") as f:
                f.write(hlo)
        rec["hlo_lines"] = hlo.count("\n")
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def main() -> None:
    import repro.configs as C
    from repro.launch import specs as sp

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(sp.SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-quant", action="store_true",
                    help="unquantized serving baseline")
    ap.add_argument("--ql", type=int, default=4)
    ap.add_argument("--fsdp", default="auto", choices=["auto", "on", "off"])
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    fsdp = {"auto": None, "on": True, "off": False}[args.fsdp]
    archs = C.ASSIGNED if (args.all or args.arch is None) else [args.arch]
    shapes = list(sp.SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                rec = run_cell(arch.replace("_", "-") if False else arch,
                               shape, mesh_kind,
                               quantize=not args.no_quant, ql=args.ql,
                               fsdp=fsdp, save_hlo=args.save_hlo)
                results.append(rec)
                line = json.dumps(rec)
                print(line if rec["status"] != "error"
                      else json.dumps({k: rec[k] for k in
                                       ("arch", "shape", "mesh", "status",
                                        "error")}))
                if rec["status"] == "error":
                    print(rec["traceback"])
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(line + "\n")

    ok = sum(r["status"] == "ok" for r in results)
    sk = sum(r["status"] == "skipped" for r in results)
    err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run: {ok} ok, {sk} skipped, {err} errors "
          f"/ {len(results)} cells")
    if err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
