"""Deterministic synthetic LM data pipeline (per-host sharded, resumable).

A real deployment would plug a tokenized corpus reader here; the interface
is what matters for the framework: per-host sharding (each data-parallel
host reads only its slice), deterministic regeneration from (seed, step)
so restarts resume exactly, and state small enough to live in every
checkpoint.  The synthetic stream is a Zipf-ish unigram mixture with
Markov structure so the LM loss actually decreases during the examples.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    frontend: Optional[str] = None     # "vision"/"audio": adds stub embeds
    frontend_tokens: int = 0
    d_model: int = 0


@dataclasses.dataclass
class DataState:
    step: int = 0


class SyntheticLM:
    """Markov-chain token stream: next ~ P(.|cur) with banded transitions."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.global_batch % cfg.n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.local_batch = cfg.global_batch // cfg.n_hosts
        self.state = DataState()

    def _batch_rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, self.cfg.host_id]))

    def next_batch(self) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = self._batch_rng(self.state.step)
        b, t, v = self.local_batch, cfg.seq_len, cfg.vocab
        # banded Markov structure: next token near 2*cur mod v, noised
        cur = rng.integers(0, v, size=(b,))
        toks = np.empty((b, t + 1), np.int32)
        toks[:, 0] = cur
        noise = rng.integers(-3, 4, size=(b, t))
        jump = rng.random((b, t)) < 0.1
        jumps = rng.integers(0, v, size=(b, t))
        for i in range(t):
            cur = (2 * cur + 1 + noise[:, i]) % v
            cur = np.where(jump[:, i], jumps[:, i], cur)
            toks[:, i + 1] = cur
        batch = {"tokens": toks}
        if cfg.frontend in ("vision", "audio") and cfg.frontend_tokens:
            batch["prefix_embeds" if cfg.frontend == "vision" else
                  "frames"] = rng.standard_normal(
                (b, cfg.frontend_tokens, cfg.d_model)).astype(np.float32)
        self.state.step += 1
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()

    # --- checkpointable state -------------------------------------------
    def state_dict(self) -> Dict:
        return {"step": self.state.step}

    def load_state_dict(self, d: Dict) -> None:
        self.state.step = int(d["step"])
